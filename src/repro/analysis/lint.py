"""AST invariant linter engine.

Walks every ``.py`` file under ``src/`` and ``tests/``, parses it once, and
runs the pluggable rules from ``repro.analysis.rules`` over the tree. Rules
yield :class:`Finding`s carrying ``file:line`` + a stable rule id.

Suppression, in order of precedence:

* **pragma** — a ``# repro: allow[rule-id]`` comment on the finding's line
  (or the line directly above, for statements too long to annotate inline)
  suppresses that rule there. Several ids may share one pragma:
  ``# repro: allow[seeded-rng,no-wallclock]``.
* **allowlist** — ``ALLOWLIST`` maps rule ids to repo-relative glob
  patterns whose files are exempt wholesale. Kept deliberately tiny: the
  pragma (which sits next to the offending line and can carry a why-note)
  is the preferred mechanism.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

# Files the linter scans, relative to the repo root.
LINT_ROOTS = ("src", "tests")

# rule id -> repo-relative glob patterns exempt from that rule.
ALLOWLIST: Dict[str, Sequence[str]] = {
    # compat.py and launch/mesh.py ARE the sanctioned shim sites: the rule
    # exists to funnel version probes into them.
    "compat-shim": ("src/repro/compat.py", "src/repro/launch/mesh.py"),
}

_PRAGMA_RE = re.compile(r"#\s*repro:\s*allow\[([A-Za-z0-9_\-, ]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str  # repo-relative posix path
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


@dataclasses.dataclass
class SourceFile:
    """One parsed file handed to every rule."""

    path: Path  # absolute
    rel: str  # repo-relative posix
    source: str
    tree: ast.Module
    pragmas: Dict[int, Set[str]]  # line -> suppressed rule ids

    @property
    def in_tests(self) -> bool:
        return self.rel.startswith("tests/")

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        return Finding(
            rule, self.rel, getattr(node, "lineno", 1), getattr(node, "col_offset", 0), message
        )


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]
    n_suppressed: int
    n_files: int
    errors: List[str]  # unparseable files

    @property
    def clean(self) -> bool:
        return not self.findings and not self.errors


def parse_pragmas(source: str) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(line)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def load_file(path: Path, root: Path) -> SourceFile:
    source = path.read_text()
    return SourceFile(
        path=path,
        rel=path.relative_to(root).as_posix(),
        source=source,
        tree=ast.parse(source, filename=str(path)),
        pragmas=parse_pragmas(source),
    )


def iter_py_files(root: Path) -> Iterable[Path]:
    for sub in LINT_ROOTS:
        base = root / sub
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.py")):
            if "__pycache__" in p.parts:
                continue
            yield p


def _suppressed(f: Finding, file: SourceFile) -> bool:
    for line in (f.line, f.line - 1):
        if f.rule in file.pragmas.get(line, ()):
            return True
    return False


def _allowlisted(rule: str, rel: str) -> bool:
    return any(fnmatch.fnmatch(rel, pat) for pat in ALLOWLIST.get(rule, ()))


def run_lint(root, rules: Optional[Sequence] = None) -> LintResult:
    """Lint the repo at ``root``; returns every unsuppressed finding."""
    from repro.analysis.rules import all_rules

    root = Path(root)
    rules = list(rules) if rules is not None else all_rules()
    files: List[SourceFile] = []
    errors: List[str] = []
    for p in iter_py_files(root):
        try:
            files.append(load_file(p, root))
        except (SyntaxError, UnicodeDecodeError) as e:
            errors.append(f"{p.relative_to(root).as_posix()}: unparseable ({e})")

    kept: List[Finding] = []
    n_suppressed = 0
    by_rel = {f.rel: f for f in files}
    for rule in rules:
        raw: List[Finding] = []
        if getattr(rule, "scope", "file") == "project":
            raw.extend(rule.check_project(files, root))
        else:
            for file in files:
                raw.extend(rule.check(file))
        for f in raw:
            if _allowlisted(f.rule, f.path):
                n_suppressed += 1
                continue
            file = by_rel.get(f.path)
            if file is not None and _suppressed(f, file):
                n_suppressed += 1
                continue
            kept.append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return LintResult(kept, n_suppressed, len(files), errors)


# -- shared AST helpers used by several rules --------------------------------


def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for nested Attribute/Name chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[str]:
    return dotted_name(node.func)
