"""CLI: ``python -m repro.analysis [--lint] [--audit] [--write]``.

With neither ``--lint`` nor ``--audit``, runs both. Exit code 0 iff every
requested pass is clean:

* lint — zero unsuppressed findings (and zero unparseable files);
* audit — zero ``shape-error`` cells AND the derived matrix's statuses
  match the committed ``support_matrix.json`` snapshot. ``--write``
  regenerates ``SUPPORT_MATRIX.md`` + ``support_matrix.json`` instead of
  diffing (commit the result).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

MATRIX_MD = "SUPPORT_MATRIX.md"
MATRIX_JSON = "support_matrix.json"


def _default_root() -> Path:
    # src/repro/analysis/__main__.py -> repo root
    return Path(__file__).resolve().parents[3]


def run_lint_pass(root: Path) -> int:
    from repro.analysis.lint import run_lint

    res = run_lint(root)
    for err in res.errors:
        print(f"lint: ERROR {err}")
    for f in res.findings:
        print(f.format())
    print(
        f"lint: {len(res.findings)} finding(s), {res.n_suppressed} suppressed, "
        f"{res.n_files} files"
    )
    return 0 if res.clean else 1


def run_audit_pass(root: Path, write: bool) -> int:
    from repro.analysis.abstract import (
        audit_all,
        compare_matrices,
        render_markdown,
        shape_error_cells,
        to_json,
    )

    matrix = audit_all()
    fresh = to_json(matrix)
    bugs = shape_error_cells(matrix)
    for c in bugs:
        print(f"audit: SHAPE-ERROR {c.config} × {c.path}: {c.detail}")

    md_path, json_path = root / MATRIX_MD, root / MATRIX_JSON
    if write:
        md_path.write_text(render_markdown(matrix))
        json_path.write_text(json.dumps(fresh, indent=2, sort_keys=True) + "\n")
        print(f"audit: wrote {md_path.name} + {json_path.name}")
        return 1 if bugs else 0

    if not json_path.is_file():
        print(f"audit: no committed {MATRIX_JSON} — run with --write and commit it")
        return 1
    committed = json.loads(json_path.read_text())
    problems = compare_matrices(committed, fresh)
    for p in problems:
        print(f"audit: {p}")
    n_cells = sum(len(v) for v in fresh["configs"].values())
    print(
        f"audit: {len(fresh['configs'])} configs × {len(fresh['paths'])} paths "
        f"({n_cells} cells), {len(bugs)} shape-error(s), {len(problems)} drift(s)"
    )
    if problems:
        print("audit: matrix drifted — if intended, regenerate with "
              "`python -m repro.analysis --audit --write` and commit")
    return 1 if (bugs or problems) else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--lint", action="store_true", help="run the AST invariant linter")
    ap.add_argument("--audit", action="store_true", help="run the eval_shape support audit")
    ap.add_argument("--write", action="store_true",
                    help="with --audit: regenerate the committed matrix snapshots")
    ap.add_argument("--list-rules", action="store_true", help="list lint rule ids and exit")
    ap.add_argument("--root", type=Path, default=None, help="repo root (default: auto)")
    args = ap.parse_args(argv)

    root = args.root or _default_root()
    if args.list_rules:
        from repro.analysis.rules import all_rules

        for r in all_rules():
            print(f"{r.id}: {r.doc}")
        return 0

    do_lint = args.lint or not (args.lint or args.audit)
    do_audit = args.audit or not (args.lint or args.audit)
    rc = 0
    if do_lint:
        rc |= run_lint_pass(root)
    if do_audit:
        rc |= run_audit_pass(root, write=args.write)
    return rc


if __name__ == "__main__":
    sys.exit(main())
