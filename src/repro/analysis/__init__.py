"""Static analysis for the repo's documented contracts.

Two passes, both device-free and fast enough for every CI run:

* **AST invariant linter** (``repro.analysis.lint`` + ``repro.analysis.rules``)
  — pluggable ``ast``-based rules over ``src/`` and ``tests/`` enforcing the
  contracts ROADMAP.md records but reviewers previously enforced by hand:
  compat shims only in ``repro/compat.py`` / ``launch/mesh.py``, tier-1 test
  imports restricted to stdlib+numpy+jax+pytest+repro, seeded RNG only,
  no wall-clock reads in discrete-event serving code, jit cache hygiene,
  and kernel/ref pairing. Findings are suppressible per line via
  ``# repro: allow[rule-id]`` pragmas or per file via the allowlist in
  ``repro.analysis.lint``.

* **Abstract support audit** (``repro.analysis.abstract``) — traces every
  registered model config through each serving feature path under
  ``jax.eval_shape`` (zero device execution) and classifies each
  config × path cell as ``supported`` / ``rejected`` (explicit
  ``NotImplementedError``) / ``shape-error`` (a bug). The result is the
  generated ``SUPPORT_MATRIX.md`` + ``support_matrix.json`` snapshots at the
  repo root; CI re-derives the matrix and fails on any regression.

Entry point: ``python -m repro.analysis [--lint] [--audit] [--write]``.
"""
from __future__ import annotations

from repro.analysis.lint import Finding, LintResult, run_lint  # noqa: F401
from repro.analysis.abstract import (  # noqa: F401
    FEATURE_PATHS,
    audit_config,
    audit_all,
    compare_matrices,
    render_markdown,
)
