"""Abstract (``jax.eval_shape``) config × feature-path support audit.

Traces every registered architecture config through each serving feature
path with **zero device execution**: model parameters and caches enter as
``jax.ShapeDtypeStruct`` avals (via the schemas' ``abstract_from_schema``)
and the whole probe runs under ``jax.eval_shape``, so nothing is lowered,
compiled, or dispatched. Each (config, path) cell is classified:

* ``supported``   — the trace completes; the path exists for this config;
* ``rejected``    — the model raised an explicit ``NotImplementedError``
  (a *documented* gap: e.g. paged KV over mamba/MLA/ring slots), or the
  path is structurally n/a for the family (classifiers have no decode);
* ``shape-error`` — any *other* exception: a silent support gap or shape
  bug. These fail the audit unconditionally.

The result is rendered to ``SUPPORT_MATRIX.md`` + ``support_matrix.json``
at the repo root; CI re-derives the matrix on every run and fails when any
cell's *status* changed vs the committed snapshot (details/messages are
excluded from the diff so wording changes don't churn CI). Regenerate with
``python -m repro.analysis --audit --write``.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, PAPER_IDS, get_config
from repro.models import build_model
from repro.models.common import abstract_from_schema

# Probe sizes: tiny batch/seq so traced constants (e.g. the zero-filled
# cache prefill materializes) stay negligible; model *weights* are always
# abstract, so the full published widths/depths trace for free.
B = 2  # batch (slots)
S = 8  # prompt length
CHUNK = 4  # chunked-prefill first-chunk length (< CACHE_LEN)
CACHE_LEN = 16  # decode cache length
N_FRAMES = 8  # enc-dec source frames
BLOCK_SIZE = 4  # paged KV tokens per block
N_BLOCKS = 16  # paged KV pool blocks
MAX_BLOCKS = CACHE_LEN // BLOCK_SIZE  # per-row block-table width

STATUS_SUPPORTED = "supported"
STATUS_REJECTED = "rejected"
STATUS_ERROR = "shape-error"

# (path id, one-line description) — column order of the matrix.
FEATURE_PATHS: Tuple[Tuple[str, str], ...] = (
    ("prefill", "full-prompt prefill (or single-shot forward for classifier families)"),
    ("decode_dense", "single-token decode, dense masked-sdpa cache attention"),
    ("decode_kernel", "single-token decode through kernels/decode_attention (flash-decode)"),
    ("decode_paged", "single-token decode over the paged block-pool cache"),
    ("chunked_prefill", "first-chunk prefill into a cache longer than the chunk"),
    ("paged_block_schema", "paged (block-pool) cache schema construction"),
    ("ramp_heads", "forward with active early-exit ramp heads"),
    ("decode_fused_exit", "multi-step fused-exit decode window (lax.while_loop + on-device thresholds)"),
    ("decode_sharded", "tensor-parallel sharded decode (tp=2): column-sharded attn/MLP, per-device KV shard"),
)
PATH_IDS = tuple(p for p, _ in FEATURE_PATHS)

ALL_CONFIG_IDS = tuple(PAPER_IDS) + tuple(ARCH_IDS)


@dataclasses.dataclass(frozen=True)
class Cell:
    config: str
    path: str
    status: str
    detail: str = ""


def _aval(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _tokens(cfg, b, s):
    return _aval((b, s), jnp.int32)


def _image_embeds(cfg, b):
    return _aval((b, cfg.n_image_tokens, cfg.d_frontend), jnp.float32)


def _frames(cfg, b):
    return _aval((b, N_FRAMES, cfg.d_frontend), jnp.float32)


def _routed_attn_slots(model) -> List:
    """Slots whose single-token decode goes through kernels/decode_attention
    (transformer._block: local windowed layers keep the dense path)."""
    cfg = model.cfg
    return [
        s
        for s in model.plan.layer_specs()
        if s.mixer == "attn" and not (s.is_local and cfg.window)
    ]


def _lm_prefill(model, cfg, *, s, cache_len, active=None):
    params = abstract_from_schema(model.schema())
    extra = {}
    if cfg.cross_attn_every:
        extra["image_embeds"] = _image_embeds(cfg, B)

    def fn(p, toks, act=None, **kw):
        return model.prefill(
            p, toks, cache_len=cache_len, active_sites=act,
            moe_impl="dense", with_cache=True, **kw,
        )

    args = [params, _tokens(cfg, B, s)]
    if active is not None:
        args.append(jnp.arange(active, dtype=jnp.int32))
    else:
        args.append(None)
    return jax.eval_shape(fn, *args, **extra)


def _lm_decode(cfg, *, decode_attn, paged=False, active=None):
    model = build_model(cfg.replace(decode_attn=decode_attn))
    params = abstract_from_schema(model.schema())
    if paged:
        cache = abstract_from_schema(
            model.paged_cache_schema(N_BLOCKS, BLOCK_SIZE)
        )  # raises NotImplementedError for non-pageable slots
        # the runner widens every shipped table by the trailing pinned
        # xkv columns (cross-attention encoder pages)
        nbx = model.paged_xkv_blocks(BLOCK_SIZE)
        tables = _aval((B, MAX_BLOCKS + nbx), jnp.int32)
        pos = _aval((B,), jnp.int32)

        def fn(p, c, toks, po, tb, act):
            return model.decode(
                p, c, toks, po, active_sites=act, moe_impl="dense", block_tables=tb,
            )

        args = (params, cache, _tokens(cfg, B, 1), pos, tables)
    else:
        cache = abstract_from_schema(model.cache_schema(B, CACHE_LEN))
        pos = _aval((B,), jnp.int32)

        def fn(p, c, toks, po, act):
            return model.decode(p, c, toks, po, active_sites=act, moe_impl="dense")

        args = (params, cache, _tokens(cfg, B, 1), pos)
    act = jnp.arange(active, dtype=jnp.int32) if active else None
    return jax.eval_shape(fn, *args, act)


def _lm_decode_fused(cfg):
    """Multi-step fused-exit decode window: ``decode_multi`` traces a
    2-step ``lax.while_loop`` with a device-resident (K,) threshold vector
    and bucket-padding row mask. The window is family-agnostic: the loop
    advances EVERY row exactly ``n_done`` steps, so recurrent (mamba),
    MLA, and ring-window caches stay consistent without per-family
    carve-outs."""
    model = build_model(cfg)
    params = abstract_from_schema(model.schema())
    cache = abstract_from_schema(model.cache_schema(B, CACHE_LEN))
    k = _n_active(model)

    def fn(p, c, toks, po, act, thr, valid, n):
        return model.decode_multi(
            p, c, toks, po, n, n_max=2,
            active_sites=act, thresholds=thr, row_valid=valid,
            moe_impl="dense",
        )

    return jax.eval_shape(
        fn, params, cache, _tokens(cfg, B, 1), _aval((B,), jnp.int32),
        jnp.arange(k, dtype=jnp.int32), _aval((k,), jnp.float32),
        _aval((B,), jnp.bool_), _aval((), jnp.int32),
    )


def _lm_decode_sharded(cfg, tp: int = 2):
    """Tensor-parallel decode probe under an ABSTRACT mesh: no devices, no
    shard_map. ``tp_check`` raises the documented per-mixer rejections;
    the trace then runs ``decode`` with a ``TpCtx`` whose gather is a
    shape-only stub (tiled all_gather == concat along the gathered axis)
    over per-device avals shrunk according to ``tp_param_specs`` /
    ``tp_cache_specs`` — exactly the shapes each device sees inside
    ``decode_sharded``'s shard_map body."""
    from repro.models import layers as LY
    from repro.models.transformer import TpCtx

    model = build_model(cfg.replace(decode_attn="dense"))
    model.tp_check(tp, dp=1, paged=False)
    axes = LY.TEST_AXES
    params = abstract_from_schema(model.schema())
    cache = abstract_from_schema(model.cache_schema(B, CACHE_LEN))

    def shrink(avals, specs):
        def one(a, sp):
            shape = list(a.shape)
            for i, s in enumerate(sp):
                if s is not None:
                    shape[i] //= tp
            return _aval(shape, a.dtype)

        return jax.tree.map(one, avals, specs)

    params = shrink(params, model.tp_param_specs(axes))
    cache = shrink(cache, model.tp_cache_specs(cache, axes))
    ctx = TpCtx(tp, lambda y: jnp.concatenate([y] * tp, axis=-1), None)

    def fn(p, c, toks, po):
        return model.decode(p, c, toks, po, moe_impl="dense", tp=ctx)

    return jax.eval_shape(
        fn, params, cache, _tokens(cfg, B, 1), _aval((B,), jnp.int32)
    )


def _encdec_prefill(model, cfg, *, s, cache_len, active=None):
    params = abstract_from_schema(model.schema())
    act = jnp.arange(active, dtype=jnp.int32) if active else None

    def fn(p, fr, toks):
        return model.prefill(p, fr, toks, cache_len=cache_len, active_sites=act)

    return jax.eval_shape(fn, params, _frames(cfg, B), _tokens(cfg, B, s))


def _n_active(model) -> int:
    sites = getattr(model, "sites", ())
    if not sites:
        raise NotImplementedError("config has no feasible ramp sites")
    return min(2, len(sites))


def probe(cfg, path: str) -> None:
    """Run one (config, path) probe; raises on rejection/bug, returns on
    success. Everything traces under ``jax.eval_shape`` — no device work."""
    family = cfg.family
    model = build_model(cfg)

    if family == "lm":
        if path == "prefill":
            _lm_prefill(model, cfg, s=S, cache_len=S)
        elif path == "decode_dense":
            _lm_decode(cfg, decode_attn="dense")
        elif path == "decode_kernel":
            if not _routed_attn_slots(model):
                raise NotImplementedError(
                    "no full-attention layers route through kernels/decode_attention "
                    "(every slot is MLA, mamba, or local-windowed)"
                )
            _lm_decode(cfg, decode_attn="kernel")
        elif path == "decode_paged":
            _lm_decode(cfg, decode_attn="paged", paged=True)
        elif path == "chunked_prefill":
            _lm_prefill(model, cfg, s=CHUNK, cache_len=CACHE_LEN)
        elif path == "paged_block_schema":
            model.paged_cache_schema(N_BLOCKS, BLOCK_SIZE)
        elif path == "ramp_heads":
            _lm_prefill(model, cfg, s=S, cache_len=S, active=_n_active(model))
        elif path == "decode_fused_exit":
            _lm_decode_fused(cfg)
        elif path == "decode_sharded":
            _lm_decode_sharded(cfg)
        return

    if family == "encdec":
        if path == "prefill":
            _encdec_prefill(model, cfg, s=S, cache_len=S)
        elif path == "decode_dense":
            params = abstract_from_schema(model.schema())
            cache, _ = _encdec_prefill(model, cfg, s=S, cache_len=CACHE_LEN)

            def fn(p, c, toks, po):
                return model.decode(p, c, toks, po, active_sites=None)

            jax.eval_shape(fn, params, cache, _tokens(cfg, B, 1), _aval((), jnp.int32))
        elif path == "decode_kernel":
            raise NotImplementedError(
                "enc-dec decoder wires dense cache attention only (no decode_impl)"
            )
        elif path == "decode_paged":
            # paged decode needs decode_attn routing for the self-attn
            # layers; the cross layers gather their pinned read-only xkv
            # pages through the trailing table columns
            pm = build_model(cfg.replace(decode_attn="paged"))
            params = abstract_from_schema(pm.schema())
            cache = abstract_from_schema(pm.paged_cache_schema(N_BLOCKS, BLOCK_SIZE))
            nbx = pm.paged_xkv_blocks(BLOCK_SIZE)
            tables = _aval((B, MAX_BLOCKS + nbx), jnp.int32)

            def fn(p, c, toks, po, tb):
                return pm.decode(p, c, toks, po, active_sites=None, block_tables=tb)

            jax.eval_shape(
                fn, params, cache, _tokens(cfg, B, 1), _aval((B,), jnp.int32), tables
            )
        elif path == "paged_block_schema":
            model.paged_cache_schema(N_BLOCKS, BLOCK_SIZE)
        elif path == "chunked_prefill":
            _encdec_prefill(model, cfg, s=CHUNK, cache_len=CACHE_LEN)
        elif path == "ramp_heads":
            _encdec_prefill(model, cfg, s=S, cache_len=S, active=_n_active(model))
        elif path == "decode_fused_exit":
            params = abstract_from_schema(model.schema())
            cache, _ = _encdec_prefill(model, cfg, s=S, cache_len=CACHE_LEN)
            k = _n_active(model)

            def fn(p, c, toks, po, act, thr, valid, n):
                return model.decode_multi(
                    p, c, toks, po, n, n_max=2,
                    active_sites=act, thresholds=thr, row_valid=valid,
                    moe_impl="dense",
                )

            jax.eval_shape(
                fn, params, cache, _tokens(cfg, B, 1), _aval((B,), jnp.int32),
                jnp.arange(k, dtype=jnp.int32), _aval((k,), jnp.float32),
                _aval((B,), jnp.bool_), _aval((), jnp.int32),
            )
        elif path == "decode_sharded":
            raise NotImplementedError(
                "sharded decode wires the decoder-only LM stack; the enc-dec "
                "decoder (pinned cross-attn memory) keeps the single-device path"
            )
        return

    if family in ("encoder_cls", "resnet"):
        if family == "encoder_cls":
            x = _tokens(cfg, B, S)
        else:
            x = _aval((B, cfg.img_size, cfg.img_size, 3), jnp.float32)
        params = abstract_from_schema(model.schema())
        if path == "prefill":
            jax.eval_shape(lambda p, xx: model.forward(p, xx), params, x)
        elif path == "ramp_heads":
            active = list(model.sites[: _n_active(model)])
            jax.eval_shape(
                lambda p, xx: model.forward(p, xx, active_sites=active), params, x
            )
        else:
            raise NotImplementedError(
                f"{family} family is single-shot (no decode / incremental prefill)"
            )
        return

    raise NotImplementedError(f"unknown family {family!r}")


_WS = re.compile(r"\s+")


def _clip(msg: str, n: int = 200) -> str:
    msg = _WS.sub(" ", msg).strip()
    return msg if len(msg) <= n else msg[: n - 1] + "…"


def audit_config(name: str, paths: Sequence[str] = PATH_IDS) -> Dict[str, Cell]:
    cfg = get_config(name)
    out: Dict[str, Cell] = {}
    for path in paths:
        try:
            probe(cfg, path)
        except NotImplementedError as e:
            out[path] = Cell(name, path, STATUS_REJECTED, _clip(str(e) or "not implemented"))
        except Exception as e:  # noqa: BLE001 — any other failure IS the signal
            out[path] = Cell(name, path, STATUS_ERROR, _clip(f"{type(e).__name__}: {e}"))
        else:
            out[path] = Cell(name, path, STATUS_SUPPORTED)
    return out


def audit_all(configs: Optional[Sequence[str]] = None) -> Dict[str, Dict[str, Cell]]:
    return {name: audit_config(name) for name in (configs or ALL_CONFIG_IDS)}


# -- snapshot (json) ---------------------------------------------------------


def to_json(matrix: Dict[str, Dict[str, Cell]]) -> dict:
    return {
        "schema_version": 1,
        "probe": {
            "B": B, "S": S, "chunk": CHUNK, "cache_len": CACHE_LEN,
            "n_blocks": N_BLOCKS, "block_size": BLOCK_SIZE,
        },
        "paths": list(PATH_IDS),
        "configs": {
            name: {
                p: {"status": c.status, **({"detail": c.detail} if c.detail else {})}
                for p, c in cells.items()
            }
            for name, cells in matrix.items()
        },
    }


def compare_matrices(committed: dict, fresh: dict) -> List[str]:
    """Status-only diff. Returns human-readable drift lines; empty == pass.
    ``supported`` -> anything is a *regression*; other changes are drift
    (also failing — the snapshot must be regenerated deliberately)."""
    problems: List[str] = []
    old_cfgs = committed.get("configs", {})
    new_cfgs = fresh.get("configs", {})
    for name in sorted(set(old_cfgs) | set(new_cfgs)):
        if name not in new_cfgs:
            problems.append(f"{name}: config disappeared from the audit")
            continue
        if name not in old_cfgs:
            problems.append(f"{name}: new config not in committed snapshot (run --write)")
            continue
        old_cells, new_cells = old_cfgs[name], new_cfgs[name]
        for path in sorted(set(old_cells) | set(new_cells)):
            old = old_cells.get(path, {}).get("status")
            new = new_cells.get(path, {}).get("status")
            if old == new:
                continue
            kind = "REGRESSION" if old == STATUS_SUPPORTED else "drift"
            problems.append(f"{kind}: {name} × {path}: {old} -> {new}")
    return problems


def shape_error_cells(matrix: Dict[str, Dict[str, Cell]]) -> List[Cell]:
    return [
        c for cells in matrix.values() for c in cells.values()
        if c.status == STATUS_ERROR
    ]


# -- markdown ----------------------------------------------------------------

_GLYPH = {STATUS_SUPPORTED: "✓", STATUS_REJECTED: "—", STATUS_ERROR: "✗ BUG"}


def render_markdown(matrix: Dict[str, Dict[str, Cell]]) -> str:
    lines = [
        "# Config × feature-path support matrix",
        "",
        "<!-- GENERATED by `python -m repro.analysis --audit --write` — do not edit. -->",
        "",
        "Derived entirely under `jax.eval_shape` (abstract shapes, zero device",
        "execution). `✓` = path traces for this config; `—` = explicit",
        "`NotImplementedError` (documented gap); `✗ BUG` = unexpected",
        "shape/trace error — fails CI.",
        "",
        f"Probe sizes: B={B}, S={S}, chunk={CHUNK}, cache_len={CACHE_LEN}, "
        f"paged pool {N_BLOCKS}×{BLOCK_SIZE} tokens.",
        "",
    ]
    header = ["config"] + [p for p in PATH_IDS]
    lines.append("| " + " | ".join(header) + " |")
    lines.append("|" + "---|" * len(header))
    for name in matrix:
        cells = matrix[name]
        row = [name] + [_GLYPH.get(cells[p].status, "?") for p in PATH_IDS]
        lines.append("| " + " | ".join(row) + " |")
    lines += ["", "## Feature paths", ""]
    for pid, desc in FEATURE_PATHS:
        lines.append(f"- **{pid}** — {desc}")
    lines += ["", "## Rejected cells (explicit `NotImplementedError`)", ""]
    any_rej = False
    for name, cells in matrix.items():
        for p in PATH_IDS:
            c = cells[p]
            if c.status == STATUS_REJECTED:
                any_rej = True
                lines.append(f"- `{name}` × `{p}`: {c.detail}")
    if not any_rej:
        lines.append("(none)")
    err = [c for cells in matrix.values() for c in cells.values() if c.status == STATUS_ERROR]
    if err:
        lines += ["", "## Shape errors (BUGS)", ""]
        for c in err:
            lines.append(f"- `{c.config}` × `{c.path}`: {c.detail}")
    lines.append("")
    return "\n".join(lines)
