"""BERT-base — the paper's own encoder classifier (sentiment, 2 classes)."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="bert-base",
    family="encoder_cls",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=30522,
    norm_type="ln",
    act="gelu",
    pos_type="learned",
    max_position=512,
    n_classes=2,
)

TINY = CONFIG.replace(
    name="tiny-bert-base",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    max_position=128,
    n_classes=2,
    dtype="float32",
)
