"""Qwen3-MoE 30B-A3B [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="lm",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,  # per-expert intermediate; all layers MoE
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    moe=True,
    n_experts=128,
    top_k=8,
    n_shared_experts=0,
    moe_d_ff=768,
)

TINY = CONFIG.replace(
    name="tiny-qwen3-moe-30b-a3b",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=48,
    vocab_size=512,
    n_experts=4,
    top_k=2,
    moe_d_ff=48,
    dtype="float32",
)
