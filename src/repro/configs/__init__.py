"""Architecture config registry.

Every assigned architecture gets one module defining ``CONFIG`` (the exact
published shape) and ``TINY`` (a reduced same-family config for CPU smoke
tests). ``get_config(name)`` / ``get_tiny(name)`` resolve them.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

from repro.models.common import pad_vocab


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # 'lm' | 'encdec' | 'resnet'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # --- norm / act / positional ---
    norm_type: str = "rms"  # 'rms' | 'ln'
    act: str = "silu"  # 'silu' | 'gelu'
    pos_type: str = "rope"  # 'rope' | 'learned' | 'none'
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    tie_embeddings: bool = False
    max_position: int = 1_048_576
    # --- attention pattern ---
    window: Optional[int] = None  # sliding window for local layers
    local_global_pattern: Optional[int] = None  # N local : 1 global period
    cross_attn_every: Optional[int] = None  # VLM: cross-attn each k-th layer
    n_image_tokens: int = 1600
    d_frontend: int = 1280  # stubbed modality embedding width
    # --- MLA ---
    mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert intermediate
    first_k_dense: int = 0  # leading dense layers (deepseek-v2)
    moe_every: int = 1  # MoE each k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm: bool = False  # pure SSM (mamba2)
    hybrid_period: int = 0  # jamba: 1 attn per `period` layers
    d_inner: int = 0
    ssm_state: int = 128
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    d_conv: int = 4
    # --- enc-dec ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # --- resnet (paper CV family) ---
    resnet_blocks: Tuple[int, ...] = ()
    resnet_widths: Tuple[int, ...] = ()
    resnet_bottleneck: bool = False
    n_classes: int = 0
    img_size: int = 32
    # --- dtype ---
    dtype: str = "bfloat16"
    # --- early exits ---
    ramp_budget_slots: int = 4  # max simultaneously-active ramps (K)
    ramp_style: str = "fc"  # 'fc' (paper default: pool+final-FC) | 'mlp' (heavier, Fig 9)
    ramp_hidden: int = 256  # hidden width for 'mlp' ramp style
    mla_absorbed: bool = False  # latent-space MLA decode (beyond-paper perf)
    scan_unroll: bool = False  # fully unroll layer scans (metric lowerings)
    kv_seq_shard: bool = False  # shard KV-cache seq dim over `model` (flash-decode layout)
    windowed_cache: bool = False  # ring caches sized `window` for local layers
    # 'off' | 'interpret' (CPU validation) | 'tpu' — streaming exit-record
    # kernel for serving head stats (kernels/ramp_head)
    pallas_head: str = "off"
    # single-token decode attention against the KV cache: 'dense' (masked
    # sdpa) | 'ref' (kernels/decode_attention jnp oracle) | 'kernel'
    # (flash-decode Pallas) | 'interpret' (Pallas interpret mode, CPU).
    # 'paged' | 'paged-kernel' | 'paged-interpret' select the PAGED block
    # pool layout (jnp oracle / Pallas / Pallas-interpret): the decode
    # cache becomes a global pool of fixed-size blocks addressed through a
    # per-slot block table (see models.transformer.LM.paged_cache_schema
    # and serving.runner.BlockAllocator)
    decode_attn: str = "dense"
    train_remat: bool = True  # activation checkpointing in train_step
    remat_policy: str = "full"  # 'full' (save nothing) | 'dots' (save matmul outputs)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.d_inner else 0

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


ARCH_IDS = [
    "deepseek-v2-lite-16b",
    "qwen3-moe-30b-a3b",
    "qwen1.5-32b",
    "qwen2-1.5b",
    "deepseek-67b",
    "gemma3-4b",
    "seamless-m4t-large-v2",
    "mamba2-2.7b",
    "jamba-1.5-large-398b",
    "llama-3.2-vision-90b",
]

PAPER_IDS = ["gpt2-medium", "bert-base", "resnet50", "resnet18"]

_MODULES = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen1.5-32b": "qwen1_5_32b",
    "qwen2-1.5b": "qwen2_1_5b",
    "deepseek-67b": "deepseek_67b",
    "gemma3-4b": "gemma3_4b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mamba2-2.7b": "mamba2_2_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "llama-3.2-vision-90b": "llama3_2_vision_90b",
    "gpt2-medium": "gpt2_medium",
    "bert-base": "bert_base",
    "resnet50": "resnet50",
    "resnet18": "resnet18",
}


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_tiny(name: str) -> ArchConfig:
    return _module(name).TINY


# Benchmark stand-ins: PAPER-SHAPE (same layer count => same ramp sites as
# the full model, so the full model's latency profile applies), tiny widths
# (CPU-trainable). Used by benchmarks/ to reproduce the paper's tables.
_BENCH_REPL = {
    "gpt2-medium": dict(d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                        vocab_size=512, max_position=64, dtype="float32"),
    "bert-base": dict(d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab_size=512, max_position=64, dtype="float32"),
    "resnet18": dict(resnet_widths=(16, 32, 64, 128), img_size=16),
    "resnet50": dict(resnet_widths=(8, 8, 16, 16), img_size=16),
    "qwen2-1.5b": dict(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab_size=512, dtype="float32"),
    "bert-large": None,  # alias below
}


def get_bench(name: str) -> ArchConfig:
    base = get_config(name)
    repl = _BENCH_REPL.get(name)
    if repl is None:
        raise KeyError(f"no bench variant for {name}")
    return base.replace(name=f"bench-{name}", **repl)


# --- input shape cells -----------------------------------------------------

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

# long_500k requires sub-quadratic attention: run only for SSM / hybrid /
# mostly-windowed archs (see DESIGN.md §4).
LONG_OK = {"mamba2-2.7b", "jamba-1.5-large-398b", "gemma3-4b"}


def cell_is_runnable(arch: str, shape: str) -> bool:
    if shape == "long_500k" and arch not in LONG_OK:
        return False
    return True


def all_cells():
    for a in ARCH_IDS:
        for s in SHAPES:
            yield a, s, cell_is_runnable(a, s)
