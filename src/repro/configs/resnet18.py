"""ResNet-18 — the paper's own CV family (basic residual blocks)."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="resnet18",
    family="resnet",
    n_layers=8,  # residual blocks
    d_model=0,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=0,
    resnet_blocks=(2, 2, 2, 2),
    resnet_widths=(16, 32, 64, 128),  # thin stack — CPU-trainable
    resnet_bottleneck=False,
    n_classes=10,
    img_size=32,
    dtype="float32",
)

TINY = CONFIG.replace(
    name="tiny-resnet18",
    resnet_blocks=(1, 1),
    resnet_widths=(8, 16),
    n_layers=2,
    img_size=16,
)
