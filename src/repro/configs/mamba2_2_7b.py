"""Mamba2-2.7B — attention-free SSD [arXiv:2405.21060; unverified]."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="lm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    pos_type="none",
    ssm=True,
    d_inner=5120,
    ssm_state=128,
    ssm_headdim=64,
    ssm_ngroups=1,
    d_conv=4,
)

TINY = CONFIG.replace(
    name="tiny-mamba2-2.7b",
    n_layers=3,
    d_model=64,
    vocab_size=512,
    d_inner=128,
    ssm_state=16,
    ssm_headdim=32,
    dtype="float32",
)
