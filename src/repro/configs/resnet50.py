"""ResNet-50 — the paper's own CV family (bottleneck residual blocks)."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="resnet50",
    family="resnet",
    n_layers=16,  # number of residual blocks (ramp sites)
    d_model=0,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=0,
    resnet_blocks=(3, 4, 6, 3),
    resnet_widths=(64, 128, 256, 512),
    resnet_bottleneck=True,
    n_classes=10,
    img_size=32,
    dtype="float32",
)

TINY = CONFIG.replace(
    name="tiny-resnet50",
    resnet_blocks=(1, 1),
    resnet_widths=(8, 16),
    n_layers=2,
    img_size=16,
)
