"""SeamlessM4T-large-v2 — enc-dec multimodal backbone [arXiv:2308.11596; hf].

Assignment specifies the transformer BACKBONE only (24L d1024 16H d_ff 8192);
we build 24 encoder + 24 decoder layers at those dims. The speech frontend
is a STUB: ``input_specs()`` provides precomputed frame embeddings
(B, frames, d_frontend).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=48,  # 24 enc + 24 dec
    n_enc_layers=24,
    n_dec_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    act="gelu",
    pos_type="rope",
    d_frontend=1024,
)

TINY = CONFIG.replace(
    name="tiny-seamless-m4t-large-v2",
    n_layers=4,
    n_enc_layers=2,
    n_dec_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    d_frontend=64,
    dtype="float32",
)
