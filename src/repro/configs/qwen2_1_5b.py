"""Qwen2-1.5B — dense GQA, QKV bias, tied embeddings [arXiv:2407.10671; hf]."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    family="lm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

TINY = CONFIG.replace(
    name="tiny-qwen2-1.5b",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=512,
    dtype="float32",
)
