"""DeepSeek-V2-Lite 16B — MoE + MLA [arXiv:2405.04434; hf].

Assignment note: the bracket lists both "MoE 64e top-6" and "2 shared+160
routed"; hf DeepSeek-V2-Lite is 64 routed top-6 + 2 shared (160 routed is
V2-full). We follow the hf Lite config (see DESIGN.md §4).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="lm",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,          # dense FFN (first layer)
    vocab_size=102400,
    mla=True,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    head_dim=192,        # qk_nope + qk_rope
    moe=True,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    first_k_dense=1,
)

TINY = CONFIG.replace(
    name="tiny-deepseek-v2-lite-16b",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    kv_lora_rank=32,
    qk_nope_dim=16,
    qk_rope_dim=8,
    v_head_dim=16,
    head_dim=24,
    n_experts=4,
    top_k=2,
    n_shared_experts=1,
    moe_d_ff=32,
    dtype="float32",
)
