"""Llama-3.2-Vision-90B — text backbone with cross-attn image layers
[hf:meta-llama/Llama-3.2-Vision family; unverified].

100 layers total; every 5th layer is a gated cross-attention block over
stubbed image patch embeddings (B, n_image_tokens, d_frontend)."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="lm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_every=5,
    n_image_tokens=1600,
    d_frontend=1280,
)

TINY = CONFIG.replace(
    name="tiny-llama-3.2-vision-90b",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=512,
    cross_attn_every=5,
    n_image_tokens=16,
    d_frontend=32,
    dtype="float32",
)
