"""Qwen1.5-32B — dense, QKV bias [hf:Qwen/Qwen1.5 family; hf].

Assignment pins kv=40 (MHA); we follow the assignment.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="lm",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

TINY = CONFIG.replace(
    name="tiny-qwen1.5-32b",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=160,
    vocab_size=512,
    dtype="float32",
)
