"""Jamba-1.5-Large 398B — hybrid Mamba+attention 1:7, MoE 16e top-2
[arXiv:2403.19887; hf].

Layer pattern: one attention layer per 8 (hybrid_period=8, attention at
layer index ≡ 4 mod 8 matching the published block layout); MoE FFN every
other layer (moe_every=2), dense FFN otherwise.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="lm",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    pos_type="none",  # jamba uses no positional encoding (mamba carries order)
    hybrid_period=8,
    ssm=False,
    d_inner=16384,
    ssm_state=128,
    ssm_headdim=64,
    ssm_ngroups=1,
    d_conv=4,
    moe=True,
    n_experts=16,
    top_k=2,
    moe_every=2,
    moe_d_ff=24576,
)

TINY = CONFIG.replace(
    name="tiny-jamba-1.5-large-398b",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    hybrid_period=4,
    d_inner=128,
    ssm_state=16,
    ssm_headdim=32,
    n_experts=4,
    top_k=2,
    moe_d_ff=128,
    dtype="float32",
)
