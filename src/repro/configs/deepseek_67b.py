"""DeepSeek-67B — dense llama-arch GQA [arXiv:2401.02954; hf]."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b",
    family="lm",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
)

TINY = CONFIG.replace(
    name="tiny-deepseek-67b",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=512,
    dtype="float32",
)
