"""GPT2-medium — the paper's own decoder-only NLP model (345M)."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="gpt2-medium",
    family="lm",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=50257,
    norm_type="ln",
    act="gelu",
    pos_type="learned",
    max_position=1024,
    tie_embeddings=True,
    n_classes=2,  # paper serves GPT2 for sentiment analysis (2-way)
)

TINY = CONFIG.replace(
    name="tiny-gpt2-medium",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    max_position=512,
    dtype="float32",
)
