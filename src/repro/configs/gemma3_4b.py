"""Gemma3-4B — dense, 5 local (window 1024) : 1 global, qk-norm, tied
embeddings [hf:google/gemma-3 family; unverified]."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="lm",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    act="gelu",
    qk_norm=True,
    tie_embeddings=True,
    window=1024,
    local_global_pattern=5,  # 5 local : 1 global
    rope_theta=1_000_000.0,
)

TINY = CONFIG.replace(
    name="tiny-gemma3-4b",
    n_layers=6,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    window=16,
    local_global_pattern=2,
    dtype="float32",
)
