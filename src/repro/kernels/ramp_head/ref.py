"""Pure-jnp oracle for the ramp-head confidence kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ramp_head_stats_ref(h: jax.Array, w: jax.Array):
    """Returns (m, s, t, argmax) with the same semantics as the kernel."""
    logits = jnp.dot(
        h.astype(jnp.float32), w.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    m = jnp.max(logits, axis=-1)
    e = jnp.exp(logits - m[:, None])
    s = jnp.sum(e, axis=-1)
    t = jnp.sum(logits * e, axis=-1)
    idx = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return m, s, t, idx


def ramp_head_exit_ref(h: jax.Array, w: jax.Array, thresholds: jax.Array):
    """Oracle for the fused exit kernel: stats plus the per-row exit mask
    ``(1 − maxprob) < threshold``. Strict ``<`` — a zero threshold can
    never trigger an exit (``simulate_exits`` semantics)."""
    m, s, t, idx = ramp_head_stats_ref(h, w)
    unc = 1.0 - 1.0 / s  # maxprob = 1/s on the streaming accumulators
    mask = (unc < thresholds.astype(jnp.float32)).astype(jnp.int32)
    return m, s, t, idx, mask


def stats_to_confidence(m, s, t, idx):
    """(label, maxprob, entropy, lse) from the streaming accumulators."""
    lse = m + jnp.log(s)
    maxprob = 1.0 / s  # exp(m - lse)
    entropy = lse - t / s  # H = lse − E[l]
    return idx, maxprob, entropy, lse
