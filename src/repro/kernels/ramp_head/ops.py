"""Jitted wrapper: ramp confidence records from pooled hiddens."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ramp_head.kernel import ramp_head_exit, ramp_head_stats
from repro.kernels.ramp_head.ref import (
    ramp_head_exit_ref,
    ramp_head_stats_ref,
    stats_to_confidence,
)


@partial(jax.jit, static_argnames=("use_kernel", "interpret", "block_v"))
def ramp_confidence(
    h: jax.Array,
    w: jax.Array,
    *,
    use_kernel: bool = True,
    interpret: bool = False,
    block_v: int = 1024,
):
    """h: (B, d) pooled hiddens; w: (d, V) head. Returns the paper's per-ramp
    record: {label, maxprob, entropy, lse} — O(1) memory on TPU."""
    if use_kernel:
        m, s, t, idx = ramp_head_stats(h, w, block_v=block_v, interpret=interpret)
    else:
        m, s, t, idx = ramp_head_stats_ref(h, w)
    label, maxprob, entropy, lse = stats_to_confidence(m, s, t, idx)
    return {"label": label, "maxprob": maxprob, "entropy": entropy, "lse": lse}


@partial(jax.jit, static_argnames=("use_kernel", "interpret", "block_v"))
def ramp_exit_decision(
    h: jax.Array,
    w: jax.Array,
    thresholds: jax.Array,
    *,
    use_kernel: bool = True,
    interpret: bool = False,
    block_v: int = 1024,
):
    """Fused on-device exit decision: the per-ramp record PLUS a per-row
    exit mask ``(1 − maxprob) < threshold`` — the host receives a bit per
    row instead of comparing uncertainties itself."""
    if use_kernel:
        m, s, t, idx, mask = ramp_head_exit(
            h, w, thresholds, block_v=block_v, interpret=interpret
        )
    else:
        m, s, t, idx, mask = ramp_head_exit_ref(h, w, thresholds)
    label, maxprob, entropy, lse = stats_to_confidence(m, s, t, idx)
    return {"label": label, "maxprob": maxprob, "entropy": entropy, "lse": lse,
            "exit": mask}
