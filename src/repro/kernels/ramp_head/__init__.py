from repro.kernels.ramp_head.kernel import ramp_head_exit, ramp_head_stats
from repro.kernels.ramp_head.ops import ramp_confidence, ramp_exit_decision
from repro.kernels.ramp_head.ref import (
    ramp_head_exit_ref,
    ramp_head_stats_ref,
    stats_to_confidence,
)
