"""Fused ramp-head confidence kernel (the paper's per-ramp record, §3.2).

Computes, for pooled hidden states h (B, d) against a ramp/LM head
W (d, V): argmax label, max logit, logsumexp and Σ l·eˡ accumulators —
WITHOUT materializing the (B, V) logits in HBM. Vocab is tiled through
VMEM with an online (max, Σe, Σl·e, argmax) merge; this is the TPU-native
analogue of streaming the paper's ~1KB per-ramp records: O(V) compute,
O(1) memory.

Grid: (B/bb, V/bv) with the vocab dimension innermost (sequential
accumulation); batch tiles are parallel. All accumulators live in VMEM
output blocks whose index map ignores the vocab index.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(h_ref, w_ref, m_ref, s_ref, t_ref, idx_ref, *, bv: int, v_limit: int):
    j = pl.program_id(1)
    h = h_ref[...]
    w = w_ref[...]
    logits = jnp.dot(
        h.astype(jnp.float32), w.astype(jnp.float32), preferred_element_type=jnp.float32
    )  # (bb, bv)
    bb = logits.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    # mask padded-vocab columns (vocab rounded up for even sharding)
    logits = jnp.where(col + j * bv < v_limit, logits, -1e30)
    tile_max = jnp.max(logits, axis=-1)  # (bb,)
    tile_arg = jnp.min(
        jnp.where(logits == tile_max[:, None], col, jnp.int32(bv)), axis=-1
    ) + j * bv
    e = jnp.exp(logits - tile_max[:, None])
    tile_s = jnp.sum(e, axis=-1)
    tile_t = jnp.sum(logits * e, axis=-1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = tile_max
        s_ref[...] = tile_s
        t_ref[...] = tile_t
        idx_ref[...] = tile_arg

    @pl.when(j > 0)
    def _merge():
        m_old = m_ref[...]
        new_m = jnp.maximum(m_old, tile_max)
        a = jnp.exp(m_old - new_m)
        b = jnp.exp(tile_max - new_m)
        s_ref[...] = s_ref[...] * a + tile_s * b
        t_ref[...] = t_ref[...] * a + tile_t * b
        idx_ref[...] = jnp.where(tile_max > m_old, tile_arg, idx_ref[...])
        m_ref[...] = new_m


def _exit_kernel(h_ref, w_ref, thr_ref, m_ref, s_ref, t_ref, idx_ref, exit_ref,
                 *, bv: int, v_limit: int):
    """Fused ramp-head + uncertainty + threshold compare: the streaming
    stats kernel plus, once the last vocab tile has merged, an in-VMEM
    exit decision ``(1 − maxprob) < threshold`` per row (strict ``<``, so
    a zero threshold can never trigger — matching ``simulate_exits``).
    The per-row EXIT MASK is all that leaves the kernel beyond the stats;
    the host never has to compare uncertainties to decide an exit."""
    _kernel(h_ref, w_ref, m_ref, s_ref, t_ref, idx_ref, bv=bv, v_limit=v_limit)

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _decide():
        # after the final merge s_ref holds the full softmax normalizer:
        # maxprob = 1/s, so uncertainty = 1 − 1/s — never materializes (B,V)
        unc = 1.0 - 1.0 / s_ref[...]
        exit_ref[...] = (unc < thr_ref[...]).astype(jnp.int32)


def ramp_head_stats(
    h: jax.Array,
    w: jax.Array,
    *,
    block_b: int = 8,
    block_v: int = 1024,
    interpret: bool = False,
    v_limit: int | None = None,
):
    """h: (B, d); w: (d, V). Returns (m, s, t, argmax):
    m = max logit, s = Σ e^{l−m}, t = Σ l·e^{l−m}, argmax (B,) int32.
    Columns >= v_limit (padded vocab) are masked to −inf."""
    B, d = h.shape
    V = w.shape[1]
    bb = min(block_b, B)
    bv = min(block_v, V)
    assert B % bb == 0 and V % bv == 0, (B, V, bb, bv)
    grid = (B // bb, V // bv)
    kernel = functools.partial(_kernel, bv=bv, v_limit=v_limit if v_limit is not None else V)
    m, s, t, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bv), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        interpret=interpret,
    )(h, w)
    return m, s, t, idx


def ramp_head_exit(
    h: jax.Array,
    w: jax.Array,
    thresholds: jax.Array,
    *,
    block_b: int = 8,
    block_v: int = 1024,
    interpret: bool = False,
    v_limit: int | None = None,
):
    """Fused exit variant: h (B, d), w (d, V), thresholds (B,) f32.
    Returns (m, s, t, argmax, exit_mask) — exit_mask (B,) int32 is 1 where
    ``(1 − maxprob) < threshold`` (strict: threshold 0 precludes exiting).
    One extra (B,)-sized output vs ``ramp_head_stats``; no extra HBM."""
    B, d = h.shape
    V = w.shape[1]
    bb = min(block_b, B)
    bv = min(block_v, V)
    assert B % bb == 0 and V % bv == 0, (B, V, bb, bv)
    grid = (B // bb, V // bv)
    kernel = functools.partial(
        _exit_kernel, bv=bv, v_limit=v_limit if v_limit is not None else V
    )
    m, s, t, idx, mask = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bv), lambda i, j: (0, j)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        interpret=interpret,
    )(h, w, thresholds.astype(jnp.float32))
    return m, s, t, idx, mask
