"""Jitted wrappers for flash-decode (contiguous and paged layouts)."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.paged import paged_decode_attention
from repro.kernels.decode_attention.paged_mla import paged_mla_decode_attention
from repro.kernels.decode_attention.ref import (
    decode_attention_ref,
    paged_decode_attention_ref,
    paged_mla_decode_attention_ref,
)


@partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def attend_decode(q, k, v, pos, *, use_kernel=True, interpret=False):
    if use_kernel:
        return decode_attention(q, k, v, pos, interpret=interpret)
    return decode_attention_ref(q, k, v, pos)


@partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def attend_decode_paged(q, k_pool, v_pool, block_table, pos, *,
                        use_kernel=True, interpret=False):
    if use_kernel:
        return paged_decode_attention(q, k_pool, v_pool, block_table, pos,
                                      interpret=interpret)
    return paged_decode_attention_ref(q, k_pool, v_pool, block_table, pos)


@partial(jax.jit, static_argnames=("scale", "use_kernel", "interpret"))
def attend_decode_paged_mla(q_lat, q_pe, c_pool, kpe_pool, block_table, pos,
                            *, scale, use_kernel=True, interpret=False):
    if use_kernel:
        return paged_mla_decode_attention(
            q_lat, q_pe, c_pool, kpe_pool, block_table, pos,
            scale=scale, interpret=interpret,
        )
    return paged_mla_decode_attention_ref(
        q_lat, q_pe, c_pool, kpe_pool, block_table, pos, scale=scale
    )
