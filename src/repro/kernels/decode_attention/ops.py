"""Jitted wrapper for flash-decode."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


@partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def attend_decode(q, k, v, pos, *, use_kernel=True, interpret=False):
    if use_kernel:
        return decode_attention(q, k, v, pos, interpret=interpret)
    return decode_attention_ref(q, k, v, pos)
