from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ops import attend_decode, attend_decode_paged
from repro.kernels.decode_attention.paged import paged_decode_attention
from repro.kernels.decode_attention.ref import (
    decode_attention_ref,
    paged_decode_attention_ref,
)
