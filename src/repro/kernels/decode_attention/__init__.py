from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ops import (
    attend_decode,
    attend_decode_paged,
    attend_decode_paged_mla,
)
from repro.kernels.decode_attention.paged import paged_decode_attention
from repro.kernels.decode_attention.paged_mla import paged_mla_decode_attention
from repro.kernels.decode_attention.ref import (
    decode_attention_ref,
    paged_decode_attention_ref,
    paged_mla_decode_attention_ref,
)
