"""Paged flash-decode Pallas kernel: single-token query against a block
KV pool.

The cache is a global pool of fixed-size blocks ``(P, bs, KH, hd)`` plus a
per-row block table ``int32[B, nb]`` mapping virtual token position
``t`` to pool slot ``(table[b, t // bs], t % bs)``. The kernel walks the
block table per row — the table and per-row positions ride in as
scalar-prefetch operands so the KV BlockSpec index map can resolve
``table[b, j]`` before the tile DMA issues (the vLLM paged-attention
pattern). The partially-filled last block is masked the same way the
contiguous kernel masks its padded tail tile: ``kpos <= pos`` kills the
scores and ``v`` is zeroed under the mask so stale pool lanes cannot
poison the p@v dot.

Table entries past a row's allocated blocks must still be VALID pool
indices (the allocator keeps them at 0, the reserved trash block): they
are fully masked, but the index map dereferences them.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tab_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            *, bs, scale, nb, H):
    js = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)  # (1, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)  # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    pos = pos_ref[pl.program_id(0) // H]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (1, bs)
    kpos = js * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    # kpos <= pos masks both unwritten offsets of the partial last block
    # and whole unallocated blocks (their table entries point at the trash
    # block); v is zeroed so stale pool values can't poison the p@v dot
    mask = kpos <= pos
    s = jnp.where(mask, s, NEG_INF)
    v = jnp.where(mask[0][:, None], v, 0.0)
    tile_m = jnp.max(s, axis=-1)

    @pl.when(js == 0)
    def _init():
        m_ref[0] = tile_m
        p = jnp.where(mask, jnp.exp(s - tile_m[:, None]), 0.0)
        l_ref[0] = jnp.sum(p, -1)
        o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)

    @pl.when(js > 0)
    def _step():
        m_old = m_ref[0]
        m_new = jnp.maximum(m_old, tile_m)
        alpha = jnp.exp(m_old - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[0] = l_ref[0] * alpha + jnp.sum(p, -1)
        o_ref[0] = o_ref[0] * alpha[:, None] + jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_ref[0] = m_new

    @pl.when(js == nb - 1)
    def _final():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)[:, None]


def paged_decode_attention(
    q: jax.Array,  # (B, H, hd) single query token per row
    k_pool: jax.Array,  # (P, bs, KH, hd) global block pool
    v_pool: jax.Array,
    block_table: jax.Array,  # int32 (B, nb): pool block id per virtual block
    pos,  # int32 (B,): cache length - 1 per row (attend to <= pos)
    *,
    interpret: bool = False,
) -> jax.Array:
    B, H, hd = q.shape
    P, bs, KH, _ = k_pool.shape
    nb = block_table.shape[1]
    G = H // KH
    scale = 1.0 / math.sqrt(hd)
    qf = q.reshape(B * H, 1, hd)
    table = jnp.asarray(block_table, jnp.int32)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))

    def kv_map(bh, js, tab_ref, pos_ref):
        return (tab_ref[bh // H, js], 0, ((bh % H) // G), 0)

    kernel = functools.partial(_kernel, bs=bs, scale=scale, nb=nb, H=H)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block table + per-row positions
        grid=(B * H, nb),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda bh, js, tab_ref, pos_ref: (bh, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), kv_map),
            pl.BlockSpec((1, bs, 1, hd), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, hd), lambda bh, js, tab_ref, pos_ref: (bh, 0, 0)),
            pl.BlockSpec((1, 1), lambda bh, js, tab_ref, pos_ref: (bh, 0)),
            pl.BlockSpec((1, 1), lambda bh, js, tab_ref, pos_ref: (bh, 0)),
        ],
    )
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B * H, 1, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * H, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * H, 1), jnp.float32),
        ],
        interpret=interpret,
    )(table, pos_arr, qf, k_pool, v_pool)
    return o.reshape(B, H, hd).astype(q.dtype)
