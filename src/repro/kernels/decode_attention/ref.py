"""Pure-jnp oracle for flash-decode."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def paged_decode_attention_ref(q, k_pool, v_pool, block_table, pos):
    """Paged oracle. q: (B,H,hd); k_pool/v_pool: (P, bs, KH, hd) block
    pools; block_table: int32 (B, nb) mapping virtual block j of row b to
    a pool slot. Gathers each row's blocks back into the contiguous
    (B, KH, nb*bs, hd) layout and defers to ``decode_attention_ref`` — so
    when ``nb*bs`` equals the contiguous cache length the result is
    bit-identical to the unpaged path, which is exactly what the
    paged-vs-contiguous equivalence harness asserts."""
    B = q.shape[0]
    P, bs, KH, hd = k_pool.shape
    nb = block_table.shape[1]
    k = k_pool[block_table].reshape(B, nb * bs, KH, hd).transpose(0, 2, 1, 3)
    v = v_pool[block_table].reshape(B, nb * bs, KH, hd).transpose(0, 2, 1, 3)
    return decode_attention_ref(q, k, v, pos)


def paged_mla_decode_attention_ref(q_lat, q_pe, c_pool, kpe_pool,
                                   block_table, pos, *, scale):
    """Paged MLA (absorbed latent) oracle. q_lat: (B,H,r); q_pe: (B,H,dr);
    c_pool: (P, bs, r) latent pool (keys AND values); kpe_pool:
    (P, bs, dr) shared rope-key pool; block_table: int32 (B, nb). Gathers
    each row's latent blocks back into the virtually-contiguous
    (B, nb*bs, ·) layout and applies the exact absorbed decode math, so
    when ``nb*bs`` equals the contiguous cache length the result is
    bit-identical to the unpaged absorbed path."""
    B, H, r = q_lat.shape
    P, bs, _ = c_pool.shape
    nb = block_table.shape[1]
    c = c_pool[block_table].reshape(B, nb * bs, r).astype(jnp.float32)
    kp = kpe_pool[block_table].reshape(B, nb * bs, -1).astype(jnp.float32)
    s = (
        jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32), c)
        + jnp.einsum("bhn,bsn->bhs", q_pe.astype(jnp.float32), kp)
    ) * scale
    mask = jnp.arange(nb * bs)[None, None] <= jnp.asarray(pos).reshape(-1, 1, 1)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bsr->bhr", p, c).astype(q_lat.dtype)


def decode_attention_ref(q, k, v, pos):
    """q: (B,H,hd); k,v: (B,KH,S,hd); attend to cache slots <= pos.
    `pos` is an int32 scalar or a (B,) array of per-row cache lengths - 1
    (batched slot caches at staggered decode positions)."""
    B, H, hd = q.shape
    KH, S = k.shape[1], k.shape[2]
    G = H // KH
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s / math.sqrt(hd)
    mask = jnp.arange(S)[None, None] <= jnp.asarray(pos).reshape(-1, 1, 1)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p, vv.astype(jnp.float32)).astype(q.dtype)
