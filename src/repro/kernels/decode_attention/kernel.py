"""Flash-decode Pallas kernel: single-token query against a long KV cache.

The dominant cost of decode attention is streaming the KV cache HBM→VMEM;
this kernel does one pass with online-softmax accumulation (grid:
(B·H, S/bs), key tiles innermost sequential). `pos` masks cache slots
beyond the current length — a scalar (shared cache length) or an int32[B]
array of per-row lengths (batched slot caches, where continuous batching
leaves every row at a different decode position). GQA handled by
index-map head folding.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, bs, scale, n_s, S):
    js = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)  # (1, hd)
    k = k_ref[0].astype(jnp.float32)  # (bs, hd)
    v = v_ref[0].astype(jnp.float32)
    pos = pos_ref[0, 0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (1, bs)
    kpos = js * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    # (kpos < S) masks the padded tail tile when bs does not divide S —
    # those lanes hold unspecified pad values (NaN in interpret mode).
    # k is laundered through the `s` mask; v must be zeroed explicitly or
    # the masked 0-weight lanes still poison the p@v dot (0 * NaN).
    mask = (kpos <= pos) & (kpos < S)
    s = jnp.where(mask, s, NEG_INF)
    v = jnp.where(mask[0][:, None], v, 0.0)
    tile_m = jnp.max(s, axis=-1)

    @pl.when(js == 0)
    def _init():
        m_ref[0] = tile_m
        p = jnp.where(mask, jnp.exp(s - tile_m[:, None]), 0.0)
        l_ref[0] = jnp.sum(p, -1)
        o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)

    @pl.when(js > 0)
    def _step():
        m_old = m_ref[0]
        m_new = jnp.maximum(m_old, tile_m)
        alpha = jnp.exp(m_old - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[0] = l_ref[0] * alpha + jnp.sum(p, -1)
        o_ref[0] = o_ref[0] * alpha[:, None] + jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_ref[0] = m_new

    @pl.when(js == n_s - 1)
    def _final():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)[:, None]


def decode_attention(
    q: jax.Array,  # (B, H, hd) single query token
    k: jax.Array,  # (B, KH, S, hd) cache
    v: jax.Array,
    pos,  # int32 scalar or (B,): cache length - 1 per row (attend to <= pos)
    *,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, hd = q.shape
    KH, S = k.shape[1], k.shape[2]
    G = H // KH
    # cache lengths are arbitrary prompt_len + max_new sums: a non-dividing
    # bs just pads the final key tile (masked off in-kernel) instead of
    # degrading the tile size
    bs = min(block_s, S)
    n_s = (S + bs - 1) // bs
    scale = 1.0 / math.sqrt(hd)
    qf = q.reshape(B * H, 1, hd)
    kf = k.reshape(B * KH, S, hd)
    vf = v.reshape(B * KH, S, hd)
    # (B, 1) per-row position; a scalar broadcasts to every row
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1, 1), (B, 1))

    def kv_map(bh, js):
        return ((bh // H) * KH + (bh % H) // G, js, 0)

    kernel = functools.partial(_kernel, bs=bs, scale=scale, n_s=n_s, S=S)
    o, m, l = pl.pallas_call(
        kernel,
        grid=(B * H, n_s),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, js: (bh // H, 0)),
            pl.BlockSpec((1, 1, hd), lambda bh, js: (bh, 0, 0)),
            pl.BlockSpec((1, bs, hd), kv_map),
            pl.BlockSpec((1, bs, hd), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, hd), lambda bh, js: (bh, 0, 0)),
            pl.BlockSpec((1, 1), lambda bh, js: (bh, 0)),
            pl.BlockSpec((1, 1), lambda bh, js: (bh, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, 1, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * H, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * H, 1), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qf, kf, vf)
    return o.reshape(B, H, hd).astype(q.dtype)
