"""Paged flash-decode Pallas kernel for MLA latent attention.

MLA decodes against a COMPRESSED latent cache, not per-head k/v: the
pools hold one shared latent stream per layer — ``c (P, bs, r)`` (which
doubles as the value stream) and the rope key ``k_pe (P, bs, dr)``. With
the absorbed decode trick the query arrives already projected into
latent space (``q_lat = q_nope @ w_uk``), so the score is

    s[b, h, t] = (q_lat[b, h] . c[b, t] + q_pe[b, h] . k_pe[b, t]) * scale

and the context is the probability-weighted latent ``sum_t p_t c[b, t]``
— MQA-like: all H heads walk the same latent blocks, no GQA grouping.

The block walk mirrors ``paged.py``: the per-row block table and
positions ride in as scalar-prefetch operands so the latent BlockSpec
index maps resolve ``table[b, j]`` before the tile DMA issues; the
online-softmax running max / sum live in per-row output refs and the
division happens on the last block. ``kpos <= pos`` masks both the
partial last block and whole unallocated blocks (trash-block table
entries), and ``c`` is zeroed under the mask so stale pool lanes cannot
poison the p@c dot.

``scale`` must be supplied by the caller (1/sqrt(dn + dr) in MLA): it is
not derivable from the latent shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tab_ref, pos_ref, ql_ref, qp_ref, c_ref, kp_ref,
            o_ref, m_ref, l_ref, *, bs, scale, nb, H):
    js = pl.program_id(1)
    ql = ql_ref[0].astype(jnp.float32)  # (1, r)
    qp = qp_ref[0].astype(jnp.float32)  # (1, dr)
    c = c_ref[0].astype(jnp.float32)  # (bs, r)
    kp = kp_ref[0].astype(jnp.float32)  # (bs, dr)
    pos = pos_ref[pl.program_id(0) // H]
    s = (
        jnp.dot(ql, c.T, preferred_element_type=jnp.float32)
        + jnp.dot(qp, kp.T, preferred_element_type=jnp.float32)
    ) * scale  # (1, bs)
    kpos = js * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    mask = kpos <= pos
    s = jnp.where(mask, s, NEG_INF)
    cv = jnp.where(mask[0][:, None], c, 0.0)  # value stream IS the latent
    tile_m = jnp.max(s, axis=-1)

    @pl.when(js == 0)
    def _init():
        m_ref[0] = tile_m
        p = jnp.where(mask, jnp.exp(s - tile_m[:, None]), 0.0)
        l_ref[0] = jnp.sum(p, -1)
        o_ref[0] = jnp.dot(p, cv, preferred_element_type=jnp.float32)

    @pl.when(js > 0)
    def _step():
        m_old = m_ref[0]
        m_new = jnp.maximum(m_old, tile_m)
        alpha = jnp.exp(m_old - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[0] = l_ref[0] * alpha + jnp.sum(p, -1)
        o_ref[0] = o_ref[0] * alpha[:, None] + jnp.dot(p, cv, preferred_element_type=jnp.float32)
        m_ref[0] = m_new

    @pl.when(js == nb - 1)
    def _final():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)[:, None]


def paged_mla_decode_attention(
    q_lat: jax.Array,  # (B, H, r) absorbed query, latent space
    q_pe: jax.Array,  # (B, H, dr) rope query
    c_pool: jax.Array,  # (P, bs, r) latent block pool (keys AND values)
    kpe_pool: jax.Array,  # (P, bs, dr) shared rope-key block pool
    block_table: jax.Array,  # int32 (B, nb)
    pos,  # int32 (B,): attend to virtual positions <= pos
    *,
    scale: float,
    interpret: bool = False,
) -> jax.Array:
    B, H, r = q_lat.shape
    dr = q_pe.shape[-1]
    P, bs, _ = c_pool.shape
    nb = block_table.shape[1]
    qlf = q_lat.reshape(B * H, 1, r)
    qpf = q_pe.reshape(B * H, 1, dr)
    table = jnp.asarray(block_table, jnp.int32)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))

    def kv_map(bh, js, tab_ref, pos_ref):
        return (tab_ref[bh // H, js], 0, 0)

    kernel = functools.partial(_kernel, bs=bs, scale=scale, nb=nb, H=H)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block table + per-row positions
        grid=(B * H, nb),
        in_specs=[
            pl.BlockSpec((1, 1, r), lambda bh, js, tab_ref, pos_ref: (bh, 0, 0)),
            pl.BlockSpec((1, 1, dr), lambda bh, js, tab_ref, pos_ref: (bh, 0, 0)),
            pl.BlockSpec((1, bs, r), kv_map),
            pl.BlockSpec((1, bs, dr), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, r), lambda bh, js, tab_ref, pos_ref: (bh, 0, 0)),
            pl.BlockSpec((1, 1), lambda bh, js, tab_ref, pos_ref: (bh, 0)),
            pl.BlockSpec((1, 1), lambda bh, js, tab_ref, pos_ref: (bh, 0)),
        ],
    )
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B * H, 1, r), jnp.float32),
            jax.ShapeDtypeStruct((B * H, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * H, 1), jnp.float32),
        ],
        interpret=interpret,
    )(table, pos_arr, qlf, qpf, c_pool, kpe_pool)
    return o.reshape(B, H, r).astype(q_lat.dtype)
