"""Mamba2 SSD chunk kernel (state-space duality, arXiv:2405.21060).

One grid step processes one (batch, head, chunk) cell: the intra-chunk
quadratic block (attention-like, MXU-friendly (ck×ck)·(ck×hp) matmuls) plus
the running inter-chunk state recurrence. The state (hp, N) lives in a VMEM
output block whose index map ignores the chunk index — chunks form the
innermost sequential grid dimension, exactly the TPU-idiomatic replacement
for the GPU scan: the systolic array does the within-chunk work, the
sequential grid carries the recurrence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, ck, hp, n):
    jc = pl.program_id(2)
    x = x_ref[0, 0].astype(jnp.float32)  # (ck, hp)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (ck,)
    A = a_ref[0, 0]  # scalar (negative)
    Bm = b_ref[0].astype(jnp.float32)  # (ck, n)
    Cm = c_ref[0].astype(jnp.float32)  # (ck, n)

    a = dt * A  # (ck,)
    cum = jnp.cumsum(a)  # inclusive
    xdt = x * dt[:, None]

    # intra-chunk: Y = ((C Bᵀ) ⊙ L) X, L[i,j] = exp(cum_i − cum_j) for j ≤ i
    qpos = jax.lax.broadcasted_iota(jnp.int32, (ck, ck), 0)
    kpos = jax.lax.broadcasted_iota(jnp.int32, (ck, ck), 1)
    L = jnp.where(kpos <= qpos, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32) * L
    y = jnp.dot(scores, xdt, preferred_element_type=jnp.float32)

    @pl.when(jc == 0)
    def _init():
        state_ref[0, 0] = jnp.zeros((hp, n), jnp.float32)

    state_in = state_ref[0, 0]  # (hp, n)
    # inter-chunk contribution: y += exp(cum) * (C · state_inᵀ)
    y = y + jnp.dot(Cm, state_in.T, preferred_element_type=jnp.float32) * jnp.exp(cum)[:, None]
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: S' = exp(cum_last)·S + Σ_c exp(cum_last − cum_c)·(x·dt)_c ⊗ B_c
    decay_out = jnp.exp(cum[-1] - cum)  # (ck,)
    state_ref[0, 0] = state_in * jnp.exp(cum[-1]) + jnp.dot(
        (xdt * decay_out[:, None]).T, Bm, preferred_element_type=jnp.float32
    )


def ssd_chunked(
    x: jax.Array,  # (B, H, S, hp)
    dt: jax.Array,  # (B, H, S)
    A: jax.Array,  # (H,) negative
    Bm: jax.Array,  # (B, S, N)   (ngroups=1, shared across heads)
    Cm: jax.Array,  # (B, S, N)
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    """Returns (y (B,H,S,hp) f32, final_state (B,H,hp,N) f32)."""
    B, H, S, hp = x.shape
    N = Bm.shape[-1]
    ck = min(chunk, S)
    assert S % ck == 0
    nc = S // ck
    a2 = jnp.broadcast_to(A[None, :, None], (B, H, 1)).astype(jnp.float32)
    kernel = functools.partial(_kernel, ck=ck, hp=hp, n=N)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, ck, hp), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, ck), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, 1), lambda b, h, c: (b, h, 0)),
            pl.BlockSpec((1, ck, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, ck, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, ck, hp), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, hp, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hp), jnp.float32),
            jax.ShapeDtypeStruct((B, H, hp, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a2, Bm, Cm)
    return y, state
