from repro.kernels.ssd.kernel import ssd_chunked
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_chunked_ref
