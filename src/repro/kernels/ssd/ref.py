"""Oracle: reuse the model substrate's chunked SSD reference."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.mamba import ssd_ref


def ssd_chunked_ref(x, dt, A, Bm, Cm, *, chunk=64):
    """Same layout as the kernel: x (B,H,S,hp), dt (B,H,S), Bm/Cm (B,S,N).
    Returns (y (B,H,S,hp) f32, final_state (B,H,hp,N) f32)."""
    xs = jnp.swapaxes(x, 1, 2)        # (B,S,H,hp)
    dts = jnp.swapaxes(dt, 1, 2)      # (B,S,H)
    y, st = ssd_ref(xs, dts, A, Bm[:, :, None], Cm[:, :, None], chunk=chunk)
    return jnp.swapaxes(y, 1, 2), st
