"""Jitted wrapper for the SSD kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssd.kernel import ssd_chunked
from repro.kernels.ssd.ref import ssd_chunked_ref


@partial(jax.jit, static_argnames=("chunk", "use_kernel", "interpret"))
def ssd(x, dt, A, Bm, Cm, *, chunk=64, use_kernel=True, interpret=False):
    if use_kernel:
        return ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
    return ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=chunk)
