"""Pallas TPU kernels for perf-critical compute (validated in interpret
mode on CPU; see tests/test_kernels_*.py). Each subpackage: kernel.py
(pl.pallas_call + BlockSpec), ops.py (jit wrapper), ref.py (jnp oracle)."""
