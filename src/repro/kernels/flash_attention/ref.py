"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=None):
    """q: (B,H,Sq,hd); k,v: (B,KH,Sk,hd); GQA broadcast; f32 softmax."""
    B, H, Sq, hd = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    G = H // KH
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s / math.sqrt(hd)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)
