"""Flash attention (prefill) Pallas kernel: causal / sliding-window / GQA.

Grid: (B·H, Sq/bq, Sk/bk), key tiles innermost. Online-softmax
accumulators (m, l, acc) live in VMEM output blocks whose index maps
ignore the key index; the final key step normalizes. Block shapes are
MXU-aligned (bq, bk multiples of the 128 lane width at production sizes;
tests shrink them for interpret mode).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, bq, bk, scale, causal, window, n_k):
    jq = pl.program_id(1)
    jk = pl.program_id(2)
    q = q_ref[0].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)
    qpos = jq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    tile_m = jnp.max(s, axis=-1)  # (bq,)

    @pl.when(jk == 0)
    def _init():
        m_ref[0] = tile_m
        p = jnp.exp(s - tile_m[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[0] = jnp.sum(p, -1)
        o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)

    @pl.when(jk > 0)
    def _step():
        m_old = m_ref[0]
        m_new = jnp.maximum(m_old, tile_m)
        alpha = jnp.exp(m_old - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[0] = l_ref[0] * alpha + jnp.sum(p, -1)
        o_ref[0] = o_ref[0] * alpha[:, None] + jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_ref[0] = m_new

    @pl.when(jk == n_k - 1)
    def _final():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)[:, None]


def flash_attention(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, KH, Sk, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    G = H // KH
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    scale = 1.0 / math.sqrt(hd)
    qf = q.reshape(B * H, Sq, hd)
    kf = k.reshape(B * KH, Sk, hd)
    vf = v.reshape(B * KH, Sk, hd)
    n_k = Sk // bk
    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, scale=scale, causal=causal, window=window, n_k=n_k
    )

    def kv_map(bh, iq, jk):
        return ((bh // H) * KH + (bh % H) // G, jk, 0)

    o, m, l = pl.pallas_call(
        kernel,
        grid=(B * H, Sq // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, iq, jk: (bh, iq, 0)),
            pl.BlockSpec((1, bk, hd), kv_map),
            pl.BlockSpec((1, bk, hd), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, iq, jk: (bh, iq, 0)),
            pl.BlockSpec((1, bq), lambda bh, iq, jk: (bh, iq)),
            pl.BlockSpec((1, bq), lambda bh, iq, jk: (bh, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sq, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * H, Sq), jnp.float32),
            jax.ShapeDtypeStruct((B * H, Sq), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return o.reshape(B, H, Sq, hd).astype(q.dtype)
