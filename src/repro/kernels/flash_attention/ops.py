"""Jitted wrapper for flash attention."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "use_kernel", "interpret"))
def attention(q, k, v, *, causal=True, window=None, use_kernel=True, interpret=False):
    if use_kernel:
        return flash_attention(q, k, v, causal=causal, window=window, interpret=interpret)
    return attention_ref(q, k, v, causal=causal, window=window)
